"""Streaming-KPCA spectral monitoring of LM activations during training —
the paper's incremental algorithm as a training-observability tool.

Trains a tiny LM for a few hundred steps and tracks the kernel
eigenspectrum of pooled hidden features: effective rank and explained-
variance evolve as the model learns.

    PYTHONPATH=src python examples/spectral_monitor.py
"""
import jax
import jax.numpy as jnp

from repro.data.synthetic import TokenStream                     # noqa: E402
from repro.launch import steps as steps_lib                      # noqa: E402
from repro.models import lm                                      # noqa: E402
from repro.models.config import ArchConfig                       # noqa: E402
from repro.optim import make_optimizer                           # noqa: E402
from repro.optim.schedules import ScheduleConfig, make_schedule  # noqa: E402
from repro.spectral import SpectralMonitor                       # noqa: E402


def main(steps=120, batch=8, seq=64):
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                     dtype="float32")
    opt = make_optimizer("adamw")
    sched = make_schedule(ScheduleConfig(kind="cosine", lr=3e-3,
                                         warmup=20, total=steps))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, sched))
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    monitor = SpectralMonitor(capacity=96)

    for step in range(steps):
        b = stream.batch_at(jnp.int32(step))
        state, metrics = step_fn(state, b)
        if step % 20 == 0:
            h = lm.forward(state.params, cfg, b["tokens"], remat=False)
            feats = jax.device_get(h.mean(axis=1))      # (B, vocab) pooled
            stats = monitor.observe(feats[:, :64])
            print(f"step {step:4d} loss={float(metrics['loss']):.3f} "
                  f"eff_rank={stats['effective_rank']:.1f} "
                  f"explained90={stats['explained_90']} "
                  f"trace={stats['trace']:.2f}")
    print("spectral history:", [round(h["effective_rank"], 1)
                                for h in monitor.history])


if __name__ == "__main__":
    main()
