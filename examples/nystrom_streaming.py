"""Incremental Nyström with an empirical stopping rule (paper §4).

Grows the landmark set one point at a time while monitoring the
approximation error ‖K − K̃‖_F — the paper's motivating use case: decide
the subset size *empirically* instead of fixing it a priori.

    PYTHONPATH=src python examples/nystrom_streaming.py
"""
import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import kernels_fn as kf, nystrom                 # noqa: E402
from repro.data.uci_like import load_dataset                     # noqa: E402


def main(n=500, target_rel_err=0.02, check_every=10):
    X = load_dataset("magic", n=n)
    sigma = float(kf.median_heuristic(jnp.asarray(X)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    k_fro = np.linalg.norm(K)

    rng = np.random.default_rng(0)
    order = rng.permutation(n)
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[order[:10]]),
                                 capacity=256, spec=spec, dtype=jnp.float64)
    m = 10
    print(f"n={n}; growing landmarks until rel. Frobenius error "
          f"< {target_rel_err}")
    while m < 256:
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[order[m]]), spec)
        m += 1
        if m % check_every == 0:
            Kt = np.asarray(nystrom.reconstruct_tilde(state))
            rel = np.linalg.norm(K - Kt) / k_fro
            print(f"  m={m:4d}  rel_fro_err={rel:.4f}")
            if rel < target_rel_err:
                print(f"stopping: m={m} landmarks suffice "
                      f"({m / n:.1%} of the dataset)")
                break
    lam, _ = nystrom.nystrom_eigpairs(state, n)
    lam = np.sort(np.asarray(lam))[::-1]
    print(f"approximate top-5 eigenvalues of K: {lam[:5].round(2)}")


if __name__ == "__main__":
    main()
