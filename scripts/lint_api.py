#!/usr/bin/env python
"""API-surface lint: the variant matrix must stay collapsed.

The stream-step refactor folded every ``*_guarded``/``*_metered``
cartesian spelling of ``Engine`` into the composed ``step``/``step_block``
pipeline; the survivors are one-line deprecation shims confined to the
marked block in ``core/engine.py``.  This check fails if a new guarded or
metered method variant appears on ``Engine`` OUTSIDE that block — the
refactor's invariant: a cross-cutting feature is a new pipeline STAGE
(selected from the ``StreamState`` bundle at trace time), never a new
method per combination.

Grep-based on purpose: no imports, no jax, runs in milliseconds as part
of ``make lint-api`` / ``make check`` / CI.

Exit status: 0 clean, 1 violation (offending lines printed).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ENGINE = Path(__file__).resolve().parent.parent / "src/repro/core/engine.py"
SHIM_BEGIN = "legacy variant-matrix shims (deprecated)"
SHIM_END = "end legacy variant-matrix shims"
VARIANT = re.compile(r"^\s+def\s+\w*_(?:guarded|metered)\w*\s*\(")


def main() -> int:
    text = ENGINE.read_text().splitlines()
    begin = end = None
    for i, line in enumerate(text):
        if SHIM_BEGIN in line and begin is None:
            begin = i
        elif SHIM_END in line and end is None:
            end = i
    if begin is None or end is None or end <= begin:
        print(f"lint-api: shim-block markers missing or malformed in "
              f"{ENGINE} (need '{SHIM_BEGIN}' before '{SHIM_END}')")
        return 1
    bad = [(i + 1, line) for i, line in enumerate(text)
           if VARIANT.match(line) and not begin <= i <= end]
    if bad:
        print("lint-api: new *_guarded/*_metered method variants outside "
              "the deprecation shim block — add a stage to the composed "
              "Engine.step pipeline instead:")
        for lineno, line in bad:
            print(f"  {ENGINE}:{lineno}: {line.strip()}")
        return 1
    print(f"lint-api: OK ({ENGINE.name}: variant matrix stays collapsed; "
          f"shims confined to lines {begin + 1}-{end + 1})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
