"""Paper Fig. 1 reproduction: drift of the incrementally-maintained
eigendecomposition, ‖K'_{m,m} − U'Λ'U'ᵀ‖ in Frobenius / spectral / trace
norms, on Magic-like and Yeast-like data, matrices of size 20+m.

Paper protocol: seed with 20 points, stream m more, measure the difference
between the direct (batch) centered kernel matrix and the incremental
reconstruction; one run + mean over ``runs`` repetitions.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import inkpca, kernels_fn as kf
from repro.data.uci_like import load_dataset

jax.config.update("jax_enable_x64", True)


def norms(D: np.ndarray) -> dict:
    ev = np.linalg.eigvalsh((D + D.T) / 2)
    return {"fro": float(np.linalg.norm(D)),
            "spectral": float(np.abs(ev).max()),
            "trace": float(np.abs(ev).sum())}


def run_once(dataset: str, n_seed: int, n_stream: int, seed: int,
             checkpoints=(10, 40, 80, 120, 160, 200, 240, 280), *,
             adjusted: bool = True, dtype=jnp.float64) -> dict:
    X = load_dataset(dataset, n=2000, seed=seed)
    rng = np.random.default_rng(seed)
    X = X[rng.permutation(len(X))][: n_seed + n_stream]
    sigma = float(kf.median_heuristic(jnp.asarray(X)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)

    stream = inkpca.KPCAStream(jnp.asarray(X[:n_seed]),
                               capacity=n_seed + n_stream, spec=spec,
                               adjusted=adjusted, dtype=dtype)
    out = {}
    streamed = 0
    for ck in checkpoints:
        if ck > n_stream:
            break
        stream.update_block(jnp.asarray(X[n_seed + streamed: n_seed + ck]))
        streamed = ck
        n = n_seed + ck
        K = np.asarray(kf.gram_block(jnp.asarray(X[:n]), jnp.asarray(X[:n]),
                                     spec=spec))
        Keff = np.asarray(kf.center_gram(jnp.asarray(K))) if adjusted else K
        rec = np.asarray(stream.reconstruction())[:n, :n]
        out[ck] = norms(rec - Keff)
    return out


def main(runs: int = 5, n_stream: int = 280) -> dict:
    results = {}
    for dataset in ("magic", "yeast"):
        per_ck: dict = {}
        for r in range(runs):
            one = run_once(dataset, 20, n_stream, seed=r)
            for ck, ns in one.items():
                per_ck.setdefault(ck, []).append(ns)
        results[dataset] = {
            ck: {k: float(np.mean([x[k] for x in v])) for k in v[0]}
            for ck, v in per_ck.items()}
        print(f"[fig1] {dataset}: drift (mean of {runs} runs)")
        for ck, ns in results[dataset].items():
            print(f"  m=20+{ck:<4d} fro={ns['fro']:.3e} "
                  f"spec={ns['spectral']:.3e} trace={ns['trace']:.3e}")
    return results


if __name__ == "__main__":
    main()
