"""Benchmark harness entry point — one benchmark per paper table/figure:

  fig1_drift      paper Fig. 1  incremental-KPCA reconstruction drift
  fig2_nystrom    paper Fig. 2  incremental-Nyström approximation error
  flops_table     paper §3      8m³-vs-20m³ efficiency claim
  timing          (supporting)  measured incremental-vs-batch scaling
  update_scaling  (supporting)  per-update cost vs active m: fixed-capacity
                                vs bucketed dispatch (BENCH_update_scaling.json)
  multitenant     (supporting)  vmapped multi-tenant ingest vs a Python loop
                                over B streams (BENCH_multitenant.json)
  roofline        (supporting)  per-kernel achieved-vs-peak bandwidth and
                                the fused-vs-unfused gates (BENCH_roofline.json)

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer repetitions / smaller streams")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import bench_multitenant, bench_update_scaling, \
        fig1_drift, fig2_nystrom, flops_table, roofline, timing

    benches = {
        "flops_table": lambda: flops_table.main(),
        "fig1_drift": lambda: fig1_drift.main(
            runs=2 if args.quick else 5,
            n_stream=120 if args.quick else 280),
        "fig2_nystrom": lambda: fig2_nystrom.main(
            runs=1 if args.quick else 3, n=400 if args.quick else 1000),
        "timing": lambda: timing.main(),
        "update_scaling": lambda: bench_update_scaling.main(
            quick=args.quick),
        "multitenant": lambda: bench_multitenant.main(
            rounds=10 if args.quick else 20),
        "roofline": lambda: roofline.main(quick=args.quick),
    }
    failures = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} {'=' * (60 - len(name))}")
        t0 = time.time()
        try:
            fn()
            print(f"=== {name} done in {time.time() - t0:.1f}s")
        except Exception as e:      # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
