"""Self-healing layer benchmarks: probe overhead, quarantine gate cost,
heal vs from-scratch re-fit.

Three claims of the health subsystem (``core/health``) are measured:

* **Probes ride the hot path almost for free** — the guarded
  steady-state window scan (gate + per-leaf select + ONE rotating
  O(M·B) orthogonality probe per chunk) must stay within a few percent
  of the unguarded ``Engine.window_block``.  The acceptance bar is
  ≤ 5% median overhead on the healthy path at m = W = 64, M = 512.

* **Healing in place beats re-fitting from scratch** — the resync rung
  re-diagonalizes the stored m points with one m×m gram + eigh inside
  the existing capacity arrays, while the operational alternative is to
  re-stream those m points through the incremental pipeline from a
  fresh seed (m rank-one updates, each O(M_b³)).  The acceptance bar is
  heal ≥ 3× cheaper than the re-fit replay at m = 128, M = 512.  The
  batch ``refit_state`` oracle (one ``init_state`` call) is reported
  alongside for reference, and the polish rung (one QR) shows the cheap
  end of the ladder.

* **The non-finite gate actually gates** — a NaN arrival must leave the
  guarded state bitwise-identical and finite; checked in every mode and
  the reason ``--smoke`` can fail the ``make bench-smoke`` run.

Emits ``BENCH_health.json`` at the repo root.  ``--smoke`` runs a toy
configuration, skips the JSON and the perf gates (CI containers are too
noisy for a 5% bar) but still fails on non-finite output or a leaking
quarantine gate.

    PYTHONPATH=src python -m benchmarks.bench_health [--smoke]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import batch as batch_mod
from repro.core import engine as eng
from repro.core import health as hl
from repro.core import inkpca
from repro.core import kernels_fn as kf
from repro.core import window as win
from repro.testing import faults

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_health.json"


def _median_time(fn, rounds: int) -> float:
    ts = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _steady_window(capacity: int, W: int, d: int, rng, plan, spec):
    """A windowed stream advanced to m ≡ W (f32, the serving dtype)."""
    engine = eng.Engine(spec, plan, adjusted=True)
    ws = win.init_window(jnp.asarray(rng.normal(size=(4, d)), jnp.float32),
                         capacity, spec, adjusted=True, dtype=jnp.float32)
    xs = jnp.asarray(rng.normal(size=(W + 8, d)), jnp.float32)
    return engine, engine.window_block(ws, xs, window=W)


def bench_probe_overhead(capacity: int, W: int, d: int, T: int,
                         rounds: int, rng) -> dict:
    """Guarded vs unguarded steady-state window block, same chunk."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan_off = eng.UpdatePlan(dispatch="bucketed")
    plan_on = plan_off._replace(health=hl.DEFAULT_POLICY)
    engine_off, ws = _steady_window(capacity, W, d, rng, plan_off, spec)
    engine_on = eng.Engine(spec, plan_on, adjusted=True)
    xs = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    h0 = hl.init_health(jnp.float32)

    t_off = _median_time(
        lambda: engine_off.window_block(ws, xs, window=W).kpca.L, rounds)
    t_on = _median_time(
        lambda: engine_on.window_block_guarded(ws, h0, xs,
                                               window=W)[0].kpca.L, rounds)

    out_on, h_on = engine_on.window_block_guarded(ws, h0, xs, window=W)
    if not bool(jnp.isfinite(out_on.kpca.L).all()):
        raise SystemExit("[health] non-finite state out of guarded block")
    overhead = t_on / max(t_off, 1e-12) - 1.0
    row = {"capacity": capacity, "window": W, "T": T,
           "unguarded_ms": t_off * 1e3, "guarded_ms": t_on * 1e3,
           "overhead_frac": overhead,
           "probes": int(h_on.probes)}
    print(f"[health] probe overhead @ W={W}, M={capacity}, T={T}: "
          f"unguarded {t_off * 1e3:.2f} ms, guarded {t_on * 1e3:.2f} ms "
          f"({overhead * 100:+.1f}%)")
    return row


def bench_heal_vs_refit(capacity: int, m: int, d: int, rounds: int,
                        rng) -> dict:
    """Heal rungs vs the from-scratch re-fit replay at (m, M)."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(dispatch="bucketed", health=hl.DEFAULT_POLICY)
    engine = eng.Engine(spec, plan, adjusted=True)
    X = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    st = inkpca.init_state(X[:4], capacity, spec, adjusted=True,
                           dtype=jnp.float32)
    st = engine.update_block(st, X[4:])
    bad = faults.corrupt_eigvecs(st, magnitude=0.3, seed=0)

    t_polish = _median_time(lambda: hl.polish(bad).U, rounds)
    t_resync = _median_time(lambda: hl.resync(bad, spec, True).L, rounds)
    t_refit_oracle = _median_time(
        lambda: batch_mod.refit_state(bad, spec, adjusted=True).L, rounds)

    def replay():
        s = inkpca.init_state(st.X[:4], capacity, spec, adjusted=True,
                              dtype=jnp.float32)
        return engine.update_block(s, st.X[4:m]).L

    t_replay = _median_time(replay, max(1, rounds // 2))

    healed = hl.resync(bad, spec, True)
    if not bool(jnp.isfinite(healed.L).all()):
        raise SystemExit("[health] non-finite eigenvalues out of resync")
    speedup = t_replay / max(t_resync, 1e-12)
    row = {"capacity": capacity, "m": m,
           "polish_ms": t_polish * 1e3, "resync_ms": t_resync * 1e3,
           "refit_init_ms": t_refit_oracle * 1e3,
           "refit_replay_ms": t_replay * 1e3,
           "heal_speedup_vs_replay": speedup}
    print(f"[health] heal @ m={m}, M={capacity}: polish "
          f"{t_polish * 1e3:.2f} ms, resync {t_resync * 1e3:.2f} ms, "
          f"re-fit replay {t_replay * 1e3:.2f} ms "
          f"({speedup:.1f}x), init_state oracle "
          f"{t_refit_oracle * 1e3:.2f} ms")
    return row


def check_nonfinite_gate(capacity: int, d: int, rng) -> dict:
    """The quarantine gate must reject a NaN bitwise — every run, every
    mode: this is the correctness half of the smoke gate."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    engine = eng.Engine(spec, plan, adjusted=True)
    st = inkpca.init_state(jnp.asarray(rng.normal(size=(6, d)),
                                       jnp.float32), capacity, spec,
                           adjusted=True, dtype=jnp.float32)
    h = hl.init_health(jnp.float32)
    st2, h2 = engine.update_guarded(st, h, faults.nan_point(d))
    bitwise = all(bool(jnp.array_equal(a, b, equal_nan=True))
                  for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)))
    ok = bitwise and int(h2.quarantined) == 1 and bool(
        jnp.isfinite(st2.L).all())
    if not ok:
        raise SystemExit("[health] non-finite gate leaked a NaN arrival")
    print(f"[health] non-finite gate: NaN arrival rejected bitwise "
          f"(quarantined={int(h2.quarantined)})")
    return {"bitwise_reject": bitwise, "quarantined": int(h2.quarantined)}


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    if args.smoke:
        probe = bench_probe_overhead(64, 16, 8, 32, 3, rng)
        heal = bench_heal_vs_refit(64, 32, 8, 3, rng)
        gate = check_nonfinite_gate(32, 8, rng)
        print(f"[health] smoke OK (overhead "
              f"{probe['overhead_frac'] * 100:+.1f}%, heal speedup "
              f"{heal['heal_speedup_vs_replay']:.1f}x)")
        return

    probe = bench_probe_overhead(512, 64, 16, 128, 7, rng)
    heal = bench_heal_vs_refit(512, 128, 16, 7, rng)
    gate = check_nonfinite_gate(64, 16, rng)
    if probe["overhead_frac"] > 0.05:
        raise SystemExit(f"[health] probe overhead gate failed: "
                         f"{probe['overhead_frac'] * 100:.1f}% > 5%")
    if heal["heal_speedup_vs_replay"] < 3.0:
        raise SystemExit(f"[health] heal gate failed: "
                         f"{heal['heal_speedup_vs_replay']:.1f}x < 3x")
    out = {"probe_overhead": probe, "heal_vs_refit": heal,
           "nonfinite_gate": gate,
           "gates": {"probe_overhead_max": 0.05,
                     "heal_speedup_min": 3.0}}
    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"[health] wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
