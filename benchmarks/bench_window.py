"""Decremental-path benchmarks: downdate cost vs m, steady-state
window_block throughput, and landmark replacement vs from-scratch
recompute.

Three claims of the decremental subsystem are measured:

* **Downdate scales with m, not M** — ``Engine.downdate`` under bucketed
  dispatch runs the inverse ±sigma pair and the contraction at the
  active bucket M_b, so evicting from a small window in a large-capacity
  state costs O(M_b³), mirroring what PR 1 did for updates.  The fixed
  dispatch column pays capacity O(M³) at every m — the gap is the win.

* **Steady-state window_block beats the per-point windowed loop** — at
  m ≡ W the evict+ingest pair is a fixed-shape composition, so
  ``Engine.window_block`` folds a whole (T, d) block through ONE
  ``lax.scan`` dispatch with the arrival ring advanced in-graph, while
  the per-point loop pays dispatch + a host evict decision (device
  sync) for every point.  The ISSUE acceptance bar is ≥ 3× at
  m = W = 64, M = 512, T = 256 on CPU.

* **replace_landmark beats recompute-from-scratch** — swapping one
  Nyström landmark via downdate+update touches O(M_b³) eigensystem work
  plus ONE new K_{n,m} column (n kernel evals), while rebuilding the
  state from the swapped landmark set pays the full O(n·m·d) gram + the
  m×m eigh + the capacity-sized allocations.  The replace side is timed
  as the steady-state lifecycle it serves: a CHAIN of donated swaps
  (``donate=True``), so the (n, M) Knm updates in place instead of
  being copied per swap — O(n + M_b²) traffic, flat in n.  The ISSUE
  acceptance bar is ≥ 5× at m=64, M=512 on CPU.

Emits ``BENCH_window.json`` at the repo root.  ``--smoke`` runs a toy
configuration, skips the JSON, and exits non-zero on non-finite output
(the ``make bench-smoke`` gate).

    PYTHONPATH=src python -m benchmarks.bench_window [--smoke]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng, inkpca, kernels_fn as kf, nystrom

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_window.json"


def _check_finite(name: str, *arrays) -> None:
    for arr in arrays:
        if not bool(jnp.isfinite(arr).all()):
            raise SystemExit(f"[window] non-finite output in {name}")


def _median_time(fn, rounds: int) -> float:
    ts = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_downdate_scaling(capacity: int, ms, d: int, rounds: int,
                           rng) -> dict:
    """Per-downdate wall-clock at active count m: bucketed vs fixed."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    rows = []
    for m in ms:
        states = {}
        for dispatch in ("fixed", "bucketed"):
            # min_bucket below the smallest m so each m lands in its own
            # bucket rung — the staircase IS the cost-scales-with-m claim.
            plan = eng.UpdatePlan(dispatch=dispatch,
                                  min_bucket=min(32, capacity))
            engine = eng.Engine(spec, plan, adjusted=True)
            stream = inkpca.KPCAStream(
                jnp.asarray(rng.normal(size=(4, d)), jnp.float32),
                capacity, spec, adjusted=True, plan=plan)
            stream.update_block(jnp.asarray(rng.normal(size=(m - 4, d)),
                                            jnp.float32))
            state = stream.state
            # Engine.downdate is pure: time it repeatedly on one input.
            fn = lambda e=engine, s=state: e.downdate(s, int(s.m) - 1).L
            jax.block_until_ready(fn())        # compile at this bucket
            states[dispatch] = _median_time(fn, rounds)
            _check_finite(f"downdate/{dispatch}/m={m}",
                          engine.downdate(state, int(state.m) - 1).L)
        rows.append({
            "m": m,
            "downdate_ms_fixed": states["fixed"] * 1e3,
            "downdate_ms_bucketed": states["bucketed"] * 1e3,
            "speedup": states["fixed"] / states["bucketed"],
        })
        print(f"[window] downdate m={m:4d} @ M={capacity}: "
              f"fixed {rows[-1]['downdate_ms_fixed']:.1f} ms, "
              f"bucketed {rows[-1]['downdate_ms_bucketed']:.1f} ms "
              f"-> {rows[-1]['speedup']:.1f}x")
    return {"capacity": capacity, "per_m": rows}


def bench_window_block(capacity: int, W: int, T: int, d: int, rounds: int,
                       rng) -> dict:
    """Steady-state throughput: scanned window_block vs per-point loop."""
    from repro.core import inkpca, window as wnd

    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=min(32, capacity))
    stream = inkpca.KPCAStream(
        jnp.asarray(rng.normal(size=(4, d)), jnp.float32), capacity, spec,
        adjusted=True, plan=plan, window=W)
    stream.update_block(jnp.asarray(rng.normal(size=(W - 4, d)),
                                    jnp.float32))
    engine, ws = stream.engine, stream.state
    assert int(ws.kpca.m) == W
    xs = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)

    def loop():
        s = ws
        for t in range(T):
            s = wnd.ingest(engine, s, xs[t], window=W)
        return s.kpca.L

    def block():
        return engine.window_block(ws, xs, window=W).kpca.L

    jax.block_until_ready(loop())              # compile both paths
    jax.block_until_ready(block())
    t_loop = _median_time(loop, rounds)
    t_block = _median_time(block, rounds)
    _check_finite("window_block", block())
    _check_finite("window_loop", loop())
    out = {
        "capacity": capacity, "window": W, "block_T": T,
        "loop_ms": t_loop * 1e3,
        "block_ms": t_block * 1e3,
        "loop_points_per_s": T / t_loop,
        "block_points_per_s": T / t_block,
        "speedup_block": t_loop / t_block,
    }
    print(f"[window] window_block W={W} M={capacity} T={T}: "
          f"block {out['block_ms']:.1f} ms vs per-point "
          f"{out['loop_ms']:.1f} ms -> {out['speedup_block']:.1f}x "
          f"({out['block_points_per_s']:.0f} pts/s)")
    return out


def bench_replace_landmark(capacity: int, m: int, n_rows: int, d: int,
                           rounds: int, rng) -> dict:
    """replace_landmark (donated lifecycle chain) vs from-scratch."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(dispatch="bucketed",
                          min_bucket=min(128, capacity))
    engine = eng.Engine(spec, plan, adjusted=False)
    x_all = jnp.asarray(rng.normal(size=(n_rows, d)), jnp.float32)
    state = nystrom.init_nystrom(x_all, x_all[:4], capacity, spec)
    for i in range(4, m):
        state = engine.add_landmark(state, x_all, x_all[i])
    x_new = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

    # Steady-state lifecycle: each swap consumes the previous state
    # (donate=True -> Knm updates in place), rotating the victim.
    st = engine.replace_landmark(state, x_all, 0, x_new, donate=True)
    jax.block_until_ready(st.Knm)                  # compile + warm
    ts = []
    for r in range(rounds):
        t0 = time.perf_counter()
        st = engine.replace_landmark(st, x_all, (3 + 7 * r) % m, x_new,
                                     donate=True)
        jax.block_until_ready(st.Knm)
        ts.append(time.perf_counter() - t0)
    t_replace = float(np.median(ts))
    _check_finite("replace", st.Knm, st.kpca.L)

    # From-scratch: rebuild from the swapped landmark set (gram + eigh +
    # dense K_{n,m} + capacity-sized alloc — everything replace avoids).
    lm = np.asarray(st.kpca.X[:m]).copy()
    lm[m // 2] = np.asarray(x_new)
    lm = jnp.asarray(lm)

    def recompute():
        return nystrom.init_nystrom(x_all, lm, capacity, spec).Knm

    jax.block_until_ready(recompute())
    t_scratch = _median_time(recompute, rounds)
    _check_finite("recompute", recompute())
    out = {
        "capacity": capacity, "m": m, "n_rows": n_rows,
        "replace_ms": t_replace * 1e3,
        "recompute_ms": t_scratch * 1e3,
        "speedup_replace": t_scratch / t_replace,
    }
    print(f"[window] replace_landmark m={m} M={capacity} n={n_rows}: "
          f"replace {out['replace_ms']:.1f} ms vs recompute "
          f"{out['recompute_ms']:.1f} ms -> "
          f"{out['speedup_replace']:.1f}x")
    return out


def main(capacity: int = 512, d: int = 16, rounds: int = 15,
         smoke: bool = False) -> dict:
    rng = np.random.default_rng(0)
    if smoke:
        capacity, rounds = 64, 3
        ms = [8, 16]
        rep = bench_replace_landmark(capacity, 16, 128, d, rounds, rng)
        blk = bench_window_block(capacity, 8, 8, d, rounds, rng)
    else:
        ms = [16, 32, 64, 128]
        # Serving-shaped rows: the from-scratch gram is O(n·m·d) while a
        # donated replace is flat in n (one column + in-place Knm).
        rep = bench_replace_landmark(capacity, 64, 16384, 64, rounds, rng)
        # Steady-state scan vs per-point loop (ISSUE bar: >= 3x here).
        blk = bench_window_block(capacity, 64, 256, d,
                                 max(rounds // 3, 3), rng)
    down = bench_downdate_scaling(capacity, ms, d, rounds, rng)

    result = {
        "backend": jax.default_backend(),
        "dtype": "float32",
        "rounds": rounds,
        "downdate_scaling": down,
        "window_block": blk,
        "replace_landmark": rep,
        "finite": True,
    }
    if not smoke:
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
        print(f"[window] wrote {OUT_PATH}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite")
    args = ap.parse_args()
    main(smoke=args.smoke)
