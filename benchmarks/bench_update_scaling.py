"""Per-update cost vs active count m at fixed capacity M (the tentpole
claim of the bucketed-dispatch work): the seed fixed-capacity path pays
O(M³) per update regardless of m, while bucketed dispatch runs each update
at the active power-of-two bucket M_b — per-step wall-clock should grow
with the bucket, not sit flat at capacity.

Three paths are timed per m:

* ``fixed_jnp``      — seed path: ``inkpca.update_adjusted`` at capacity M
* ``bucketed_jnp``   — bucketed ``engine.Engine.update`` (slice → update
                       at M_b → scatter)
* ``bucketed_fused`` — same, with the fused ±sigma double-rotation pairs
                       (``matmul='jnp2'``: one pass over U per pair)

Emits ``BENCH_update_scaling.json`` at the repo root so the perf
trajectory is tracked across PRs.  CPU wall-clock is indicative; the
m-scaling shape (staircase across bucket crossings) is the claim.
``--smoke`` runs a toy configuration, skips the JSON, and exits non-zero
on non-finite output (the ``make bench-smoke`` gate).

    PYTHONPATH=src python -m benchmarks.bench_update_scaling [--quick|--smoke]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_update_scaling.json"

BPLAN = eng.DEFAULT_PLAN._replace(dispatch="bucketed")


def _time(fn, reps: int) -> float:
    out = fn()
    jax.block_until_ready(out)           # compile + warm caches
    if not bool(jnp.isfinite(out).all()):
        raise SystemExit("[update_scaling] non-finite update output")
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _state_at(X, m: int, capacity: int, spec) -> inkpca.KPCAState:
    """A capacity-``capacity`` adjusted state holding m active points."""
    state = inkpca.init_state(jnp.asarray(X[:4]), capacity, spec,
                              adjusted=True, dtype=jnp.float32)
    # Grow with the bucketed path (fast) — the resulting state is identical
    # to what the fixed path would produce, up to fp rounding.
    state = eng.Engine(spec, BPLAN).update_block(state, jnp.asarray(X[4:m]))
    return state


def main(capacity: int = 1024, reps: int = 3, quick: bool = False,
         smoke: bool = False) -> dict:
    if quick:
        capacity, reps = 512, 2
    if smoke:
        capacity, reps = 128, 1
    rng = np.random.default_rng(0)
    d = 16
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    ms = [m for m in (32, 64, 128, 256, 512) if m < capacity]
    X = rng.normal(size=(max(ms) + 1, d)).astype(np.float32)

    sweep = []
    print(f"[update_scaling] capacity M={capacity} (CPU wall-clock per "
          f"adjusted update)")
    print(f"{'m':>6s} {'bucket':>7s} {'fixed_jnp_ms':>13s} "
          f"{'bucketed_ms':>12s} {'fused_ms':>9s} {'speedup':>8s}")
    buck_eng = eng.Engine(spec, BPLAN)
    fused_eng = eng.Engine(spec, BPLAN._replace(matmul="jnp2"))
    for m in ms:
        state = _state_at(X, m, capacity, spec)
        x_new = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        a, k_new = inkpca._masked_row(state, x_new, spec)

        t_fixed = _time(lambda: inkpca.update_adjusted(
            state, a, k_new, x_new).L, reps)
        t_buck = _time(lambda: buck_eng.update(state, x_new).L, reps)
        t_fused = _time(lambda: fused_eng.update(state, x_new).L, reps)
        bucket = eng.bucket_for(m + 1, capacity)
        row = {"m": m, "bucket": bucket, "fixed_jnp_s": t_fixed,
               "bucketed_jnp_s": t_buck, "bucketed_fused_s": t_fused,
               "speedup_bucketed": t_fixed / t_buck}
        sweep.append(row)
        print(f"{m:6d} {bucket:7d} {t_fixed * 1e3:13.2f} "
              f"{t_buck * 1e3:12.2f} {t_fused * 1e3:9.2f} "
              f"{t_fixed / t_buck:7.2f}x")

    at128 = next((r for r in sweep if r["m"] == 128), None)
    result = {
        "capacity": capacity,
        "dtype": "float32",
        "backend": jax.default_backend(),
        "reps": reps,
        "sweep": sweep,
        "speedup_bucketed_at_m128": (at128 and at128["speedup_bucketed"]),
    }
    if smoke:
        print("[update_scaling] smoke OK (finite), JSON unchanged")
        return result
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print(f"[update_scaling] wrote {OUT_PATH}")
    if at128:
        print(f"[update_scaling] m=128 @ M={capacity}: bucketed is "
              f"{at128['speedup_bucketed']:.1f}x the seed fixed-jnp path")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite")
    args = ap.parse_args()
    main(quick=args.quick, smoke=args.smoke)
