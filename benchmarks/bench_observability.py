"""Telemetry layer benchmarks: metric-lane overhead and identity.

Two claims of the observability layer (``core/telemetry`` + ``repro.obs``)
are measured:

* **The metric lane rides the hot path almost for free** — the metered
  steady-state window block (the UNMODIFIED inner executable + one tiny
  fused ``note_block`` dispatch) must stay within a few percent of the
  plain ``Engine.window_block``.  The acceptance bar is ≤ 5% median
  overhead at m = W = 64, M = 512.

* **Metrics-off means bitwise-off** — a metrics-on stream and a
  metrics-off stream fed the same points must hold bitwise-identical
  eigensystems (the note consumes the update's outputs, it never sits
  in front of them), and the counters must match a host oracle exactly;
  checked in every mode and the reason ``--smoke`` can fail the
  ``make bench-smoke`` run.

Emits ``BENCH_observability.json`` at the repo root.  ``--smoke`` runs a
toy configuration, skips the JSON and the perf gate (CI containers are
too noisy for a 5% bar) but still fails on an identity or counter
mismatch.  ``--scrape`` runs a short decoupled serving loop with the
full export surface on and prints the resulting Prometheus scrape
(the ``make metrics`` target).

    PYTHONPATH=src python -m benchmarks.bench_observability [--smoke]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import health as hl
from repro.core import inkpca
from repro.core import kernels_fn as kf
from repro.core import telemetry as tm
from repro.core import window as win

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_observability.json"


def _median_time(fn, rounds: int) -> float:
    ts = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_metric_lane_overhead(capacity: int, W: int, d: int, T: int,
                               rounds: int, rng) -> dict:
    """Metered vs plain steady-state window block, same chunk."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(dispatch="bucketed")
    engine = eng.Engine(spec, plan, adjusted=True)
    ws = win.init_window(jnp.asarray(rng.normal(size=(4, d)), jnp.float32),
                         capacity, spec, adjusted=True, dtype=jnp.float32)
    ws = engine.window_block(ws, jnp.asarray(rng.normal(size=(W + 8, d)),
                                             jnp.float32), window=W)
    xs = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    ms0 = tm.init_metrics(jnp.float32)

    t_off = _median_time(
        lambda: engine.window_block(ws, xs, window=W).kpca.L, rounds)
    t_on = _median_time(
        lambda: engine.window_block_metered(ws, ms0, xs,
                                            window=W)[1].ingests, rounds)

    out_plain = engine.window_block(ws, xs, window=W)
    out_met, ms = engine.window_block_metered(ws, ms0, xs, window=W)
    bitwise = all(bool(jnp.array_equal(a, b)) for a, b in
                  zip(jax.tree.leaves(out_plain), jax.tree.leaves(out_met)))
    rep = tm.metrics_report(ms)
    if not bitwise:
        raise SystemExit("[obs] metered window block diverged from plain")
    if rep["ingests"] != T or rep["evictions"] != T:
        raise SystemExit(f"[obs] counter mismatch: {rep} vs T={T}")
    overhead = t_on / max(t_off, 1e-12) - 1.0
    row = {"capacity": capacity, "window": W, "T": T,
           "plain_ms": t_off * 1e3, "metered_ms": t_on * 1e3,
           "overhead_frac": overhead, "bitwise": bitwise}
    print(f"[obs] metric lane @ W={W}, M={capacity}, T={T}: "
          f"plain {t_off * 1e3:.2f} ms, metered {t_on * 1e3:.2f} ms "
          f"({overhead * 100:+.1f}%)")
    return row


def check_identity_and_counters(capacity: int, W: int, d: int, n: int,
                                rng) -> dict:
    """Metrics-on vs metrics-off streams over a mixed guarded window
    stream: bitwise state identity + exact counters (the correctness
    half of the smoke gate)."""
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    X = np.asarray(rng.normal(size=(n, d)), np.float32)
    X[n // 3] = np.nan                      # one quarantined arrival
    streams = []
    for metrics in (False, True):
        plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY, metrics=metrics)
        s = inkpca.KPCAStream(jnp.asarray(X[:4]), capacity, spec,
                              adjusted=False, plan=plan, dtype=jnp.float32,
                              window=W)
        for i in range(4, n):
            s.update(jnp.asarray(X[i]))
        streams.append(s)
    off, on = streams
    bitwise = all(bool(jnp.array_equal(a, b, equal_nan=True)) for a, b in
                  zip(jax.tree.leaves(off.state), jax.tree.leaves(on.state)))
    rep = on.metrics_report()
    offered = n - 4
    want_ing = offered - 1
    want_evict = max(0, want_ing - (W - 4))
    ok = (bitwise and rep["ingests"] == want_ing
          and rep["rejections"] == 1 and rep["evictions"] == want_evict)
    if not ok:
        raise SystemExit(f"[obs] identity/counter check failed: "
                         f"bitwise={bitwise}, report={rep}, "
                         f"want ingests={want_ing}, evictions={want_evict}")
    print(f"[obs] identity: metrics-on state bitwise == metrics-off; "
          f"counters exact over {offered} offered points")
    return {"bitwise": bitwise, "ingests": rep["ingests"],
            "rejections": rep["rejections"], "evictions": rep["evictions"]}


def scrape_demo() -> None:
    """Short decoupled serving run with the full export surface on, then
    print the Prometheus scrape — the ``make metrics`` target."""
    from repro import obs
    from repro.launch import serve

    serve.main(["--mode", "kpca", "--decouple", "--tenants", "2",
                "--capacity", "32", "--points", "12", "--dim", "4",
                "--window", "16", "--health", "--serve-every", "4",
                "--publish-on-drift", "0.05", "--metrics"])
    print("\n# --- Prometheus scrape ---")
    print(obs.get_hub().to_prometheus())


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scrape", action="store_true")
    args = ap.parse_args(argv)

    if args.scrape:
        scrape_demo()
        return

    rng = np.random.default_rng(0)
    if args.smoke:
        lane = bench_metric_lane_overhead(64, 16, 8, 32, 3, rng)
        ident = check_identity_and_counters(32, 12, 8, 30, rng)
        print(f"[obs] smoke OK (metric lane "
              f"{lane['overhead_frac'] * 100:+.1f}%)")
        return

    lane = bench_metric_lane_overhead(512, 64, 16, 128, 7, rng)
    ident = check_identity_and_counters(64, 24, 16, 80, rng)
    if lane["overhead_frac"] > 0.05:
        raise SystemExit(f"[obs] metric lane gate failed: "
                         f"{lane['overhead_frac'] * 100:.1f}% > 5%")
    out = {"metric_lane_overhead": lane, "identity": ident,
           "gates": {"metric_lane_overhead_max": 0.05}}
    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"[obs] wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
