"""Paper §3 efficiency claim: leading-order flop counts per incremental
step — ours 8m³ (adjusted) / 4m³ (unadjusted) vs ~20m³ for Chin & Suter
(2007) — plus a *measured* operation-count cross-check that the per-step
work of our implementation is dominated by the predicted 4 (resp. 2)
m×m matmuls.
"""
from __future__ import annotations

import numpy as np

from repro.core.batch import flop_model


def main() -> dict:
    sizes = (128, 256, 512, 1024, 2048)
    rows = []
    print("[flops] leading-order flops per incremental step (×m³)")
    print(f"{'m':>6s} {'ours(adj)':>12s} {'ours(unadj)':>12s} "
          f"{'chin-suter':>12s} {'rot-eigh':>12s} {'batch-eigh':>12s} "
          f"{'speedup':>8s}")
    for m in sizes:
        f = flop_model(m)
        rows.append(f)
        print(f"{m:6d} {f['ours_adjusted']:.3e} {f['ours_unadjusted']:.3e} "
              f"{f['chin_suter_2007']:.3e} {f['rotated_eigh_baseline']:.3e} "
              f"{f['batch_eigh']:.3e} "
              f"{f['chin_suter_2007'] / f['ours_adjusted']:7.2f}x")
    speedup = rows[-1]["chin_suter_2007"] / rows[-1]["ours_adjusted"]
    assert speedup == 2.5, "paper claim: >2x more efficient"
    print(f"[flops] paper claim reproduced: ours is {speedup:.1f}x cheaper "
          "than Chin & Suter (2007) per step at the O(m^3) order")
    return {"sizes": sizes, "speedup_vs_chin_suter": speedup}


if __name__ == "__main__":
    main()
