"""Render the baseline-vs-optimized §Perf comparison table from dry-run
artifacts.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline experiments/dryrun --optimized experiments/optimized
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(d: str) -> dict:
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--optimized", default="experiments/optimized")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    base = load(args.baseline)
    opt = load(args.optimized)

    print(f"{'arch':22s} {'shape':11s} | {'base C/M/N (s)':>26s} "
          f"{'roof':>8s} {'mfu':>5s} | {'opt C/M/N (s)':>26s} "
          f"{'roof':>8s} {'mfu':>5s} | {'gain':>5s}")
    rows = sorted(k for k in base if k[2] == args.mesh and k in opt)
    for k in rows:
        b, o = base[k], opt[k]
        bm = b["compute_s"] / b["roofline_s"] if b["roofline_s"] else 0
        om = o["compute_s"] / o["roofline_s"] if o["roofline_s"] else 0
        gain = b["roofline_s"] / o["roofline_s"] if o["roofline_s"] else 0
        print(f"{k[0]:22s} {k[1]:11s} | "
              f"{b['compute_s']:8.2e}/{b['memory_s']:8.2e}/"
              f"{b['collective_s']:8.2e} {b['roofline_s']:8.2e} {bm:5.2f} | "
              f"{o['compute_s']:8.2e}/{o['memory_s']:8.2e}/"
              f"{o['collective_s']:8.2e} {o['roofline_s']:8.2e} {om:5.2f} | "
              f"{gain:5.2f}x")


if __name__ == "__main__":
    main()
