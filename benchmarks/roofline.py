"""§Roofline aggregation: read the dry-run artifacts and print/emit the
per-(arch × shape × mesh) roofline table (terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio).

Run the dry-run first:
    python -m repro.launch.dryrun --all --both-meshes --out experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = "experiments/dryrun"


def load_cells(dryrun_dir: str = DEFAULT_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(r: dict) -> str:
    roof = max(r["compute_s"], 1e-30)
    frac = r["compute_s"] / r["roofline_s"] if r["roofline_s"] else 0.0
    return (f"{r['arch']:22s} {r['shape']:11s} {r['mesh']:10s} "
            f"{r['compute_s']:9.2e} {r['memory_s']:9.2e} "
            f"{r['collective_s']:9.2e} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:6.2f} {frac:9.3f}")


def main(dryrun_dir: str = DEFAULT_DIR) -> list[dict]:
    cells = load_cells(dryrun_dir)
    if not cells:
        print(f"[roofline] no dry-run artifacts in {dryrun_dir} — run "
              "python -m repro.launch.dryrun --all --both-meshes first")
        return []
    print(f"[roofline] {len(cells)} cells "
          "(terms in seconds/step; frac = compute/roofline = achievable MFU "
          "bound at this config)")
    print(f"{'arch':22s} {'shape':11s} {'mesh':10s} "
          f"{'compute':>9s} {'memory':>9s} {'collect':>9s} {'dominant':>10s} "
          f"{'useful':>6s} {'mfu-bound':>9s}")
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(fmt_row(r))
    doms = {}
    for r in cells:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"[roofline] dominant-term histogram: {doms}")
    return cells


if __name__ == "__main__":
    main()
