"""Kernel roofline for the live engine: achieved vs peak memory bandwidth
per Pallas kernel, plus the fused-vs-unfused wall-clock claims.

Peak bandwidth is measured, not quoted: a STREAM-style triad
(``a = b + s*c`` over arrays far larger than cache) gives the
machine-achievable HBM/DRAM rate on this backend, and every kernel row
reports its achieved rate as a fraction of that roofline.  Per kernel we
model the bytes that MUST move (operands in + results out, counted once —
the fused kernels exist precisely to make this model tight) and count
useful flops, so the table also shows arithmetic intensity: low-AI rows
(rbf_gram, scaled_gram at small d) should sit near the bandwidth roof,
high-AI rows (the M³ rotations) should fall off it toward compute bound.

Kernels timed (production dispatch — the ref path on CPU, compiled
Pallas on TPU; same math either way):

* ``eigvec_rotate``    one Cauchy rotation          C = U @ Wn
* ``eigvec_rotate2``   fused ±sigma double rotation C = U @ W1n @ W2n
* ``rbf_gram``         dense gram block             K = k(X, Y)
* ``krow_fused``       fused ingest prologue        (a, UᵀT[a|aux])
* ``eigvec_project``   rect-pruned pair projection  Z = Uᵀ[v1|v2]
* ``transform_batch``  fused batched transform      (K_q,masked @ S, 1ᵀ)
* ``nystrom_recon``    scaled gram reconstruction   (B·s) @ Bᵀ

The second section times the two fusion claims end-to-end at m=128
active points in a capacity M=1024 stream (f32): one adjusted ingest and
one 64-query transform, unfused at fixed capacity (the seed path) vs
fused under bucketed dispatch (the shipped path).  The headline speedups
are the acceptance gates — each must be >= 1.5x on CPU.

Emits ``BENCH_roofline.json`` at the repo root.  ``--smoke`` runs toy
sizes, skips the JSON, and exits non-zero on non-finite output or a
non-positive achieved bandwidth (the ``make bench-smoke`` gate).

    PYTHONPATH=src python -m benchmarks.roofline [--quick|--smoke]
"""
from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng, inkpca, kernels_fn as kf
from repro.kernels.eigvec_update import ops as uops
from repro.kernels.nystrom_recon import ops as nops
from repro.kernels.rbf_gram import ops as gops

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_roofline.json"
F32 = jnp.dtype(jnp.float32).itemsize


def _time(fn, reps: int) -> float:
    """Seconds per call after a compile+warmup pass; fails on non-finite."""
    out = fn()
    jax.block_until_ready(out)
    leaves = jax.tree_util.tree_leaves(out)
    if not all(bool(jnp.isfinite(v).all()) for v in leaves):
        raise SystemExit("[roofline] non-finite kernel output")
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def peak_bandwidth(n: int, reps: int) -> tuple[float, int]:
    """STREAM triad a = b + s*c: (achievable GB/s, bytes moved per pass)."""
    b = jnp.ones((n,), jnp.float32)
    c = jnp.full((n,), 0.5, jnp.float32)
    triad = jax.jit(lambda b, c: b + 1.5 * c)
    t = _time(lambda: triad(b, c), reps)
    nbytes = 3 * n * F32                       # read b, read c, write a
    return nbytes / t / 1e9, nbytes


def _row(name: str, secs: float, nbytes: int, flops: int,
         peak_gbps: float) -> dict:
    gbps = nbytes / secs / 1e9
    return {
        "kernel": name,
        "ms": secs * 1e3,
        "bytes": nbytes,
        "flops": flops,
        "ai_flop_per_byte": flops / nbytes,
        "gbps": gbps,
        "peak_gbps": peak_gbps,
        "frac_of_peak": gbps / peak_gbps,
    }


def kernel_rows(M: int, d: int, Q: int, C: int, reps: int,
                peak_gbps: float) -> list[dict]:
    rng = np.random.default_rng(0)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    f32 = jnp.float32
    u = jnp.asarray(rng.normal(size=(M, M)) / np.sqrt(M), f32)
    x = jnp.asarray(rng.normal(size=(M, d)), f32)
    xq = jnp.asarray(rng.normal(size=(Q, d)), f32)
    x_new = jnp.asarray(rng.normal(size=(d,)), f32)
    s_cols = jnp.asarray(rng.normal(size=(M, C)), f32)
    s_diag = jnp.asarray(rng.uniform(0.5, 1.5, size=(M,)), f32)
    b_rows = jnp.asarray(rng.normal(size=(Q, M)), f32)
    aux = jnp.stack([jnp.ones((M,), f32),
                     jnp.asarray(rng.normal(size=(M,)), f32)], axis=1)
    m_full = jnp.asarray(M, jnp.int32)
    # Interlaced eigenvalues/poles keep the Cauchy denominators away from 0.
    lam = jnp.linspace(0.0, 1.0, M, dtype=f32)
    dv = lam + 0.5 / M
    zhat = jnp.asarray(rng.normal(size=(M,)) / np.sqrt(M), f32)
    inv = jnp.ones((M,), f32)
    no_defl = jnp.zeros((M,), jnp.int32)
    cid = jnp.arange(M, dtype=jnp.int32)

    rot1 = jax.jit(lambda u, z, dv, l, i: uops.rotate_vectors(u, z, dv, l, i))
    rot2 = jax.jit(lambda u, z, dv, l, i, f, c:
                   uops.rotate_vectors2(u, z, dv, l, i, f, c,
                                        z, dv, l, i, f, c))
    gram = jax.jit(lambda a, b: gops.gram(a, b, spec.sigma))
    krow = jax.jit(lambda u, x, xn, aux, m:
                   gops.krow_project(u, x, xn, aux, m, spec=spec))
    tbat = jax.jit(lambda xq, x, s, m:
                   nops.transform_project(xq, x, s, m, spec=spec))
    sgram = jax.jit(lambda b, s: nops.scaled_gram(b, s))
    vpair = jnp.asarray(rng.normal(size=(M, 2)), f32)
    proj = jax.jit(lambda u, v, m: uops.project_vectors(u, v, m))

    rows = [
        _row("eigvec_rotate",
             _time(lambda: rot1(u, zhat, dv, lam, inv), reps),
             (2 * M * M + 4 * M) * F32, 2 * M**3 + 3 * M * M, peak_gbps),
        _row("eigvec_rotate2",
             _time(lambda: rot2(u, zhat, dv, lam, inv, no_defl, cid), reps),
             (2 * M * M + 12 * M) * F32, 4 * M**3 + 6 * M * M, peak_gbps),
        _row("rbf_gram",
             _time(lambda: gram(x, x), reps),
             (2 * M * d + M * M) * F32, 2 * M * M * (d + 2), peak_gbps),
        _row("krow_fused",
             _time(lambda: krow(u, x, x_new, aux, m_full), reps),
             (M * M + M * d + 2 * M + M + 3 * M) * F32,
             2 * M * d + 3 * M + 6 * M * M, peak_gbps),
        _row("eigvec_project",
             _time(lambda: proj(u, vpair, m_full), reps),
             (M * M + 2 * M + 2 * M) * F32, 4 * M * M, peak_gbps),
        _row("transform_batch",
             _time(lambda: tbat(xq, x, s_cols, m_full), reps),
             (Q * d + M * d + M * C + Q * C + Q) * F32,
             2 * Q * M * (d + C) + 3 * Q * M, peak_gbps),
        _row("nystrom_recon",
             _time(lambda: sgram(b_rows, s_diag), reps),
             (Q * M + M + Q * Q) * F32, 2 * Q * Q * M + Q * M, peak_gbps),
    ]
    return rows


def _state_at(m: int, capacity: int, d: int, spec) -> inkpca.KPCAState:
    from repro.core import engine as eng

    rng = np.random.default_rng(1)
    X = rng.normal(size=(m, d)).astype(np.float32)
    state = inkpca.init_state(jnp.asarray(X[:4]), capacity, spec,
                              adjusted=True, dtype=jnp.float32)
    return eng.Engine(spec, eng.DEFAULT_PLAN._replace(
        dispatch="bucketed")).update_block(state, jnp.asarray(X[4:]))


def fused_comparison(capacity: int, m: int, d: int, q_batch: int,
                     reps: int) -> dict:
    """End-to-end fused-vs-unfused at m active points, capacity M (f32)."""
    rng = np.random.default_rng(2)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    state = _state_at(m, capacity, d, spec)
    x_new = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(q_batch, d)), jnp.float32)

    plan_fixed = eng.UpdatePlan(matmul="jnp", dispatch="fixed")
    plan_buck = eng.UpdatePlan(matmul="jnp", dispatch="bucketed")
    plan_fused = eng.UpdatePlan(matmul="jnp2", dispatch="bucketed",
                                fuse_krow=True)
    engines = {name: eng.Engine(spec, plan, adjusted=True)
               for name, plan in (("unfused_fixed", plan_fixed),
                                  ("unfused_bucketed", plan_buck),
                                  ("fused_bucketed", plan_fused))}
    ingest = {name: _time(lambda e=e: e.update(state, x_new).L, reps)
              for name, e in engines.items()}

    tf = jax.jit(eng.transform_state,
                 static_argnames=("spec", "adjusted", "n_components", "plan"))
    n_comp = min(16, m)
    Mb = eng.bucket_for(m, capacity, plan_fused.min_bucket)
    sub = eng.slice_state(state, Mb) if Mb < capacity else state
    transform = {
        "unfused_fixed": _time(partial(
            tf, state, q, spec=spec, adjusted=True, n_components=n_comp,
            plan=None), reps),
        "fused_bucketed": _time(partial(
            tf, sub, q, spec=spec, adjusted=True, n_components=n_comp,
            plan=plan_fused.kernel_plan()), reps),
    }
    return {
        "capacity": capacity, "m": m, "dim": d, "q_batch": q_batch,
        "bucket": int(Mb),
        "ingest_ms": {k: v * 1e3 for k, v in ingest.items()},
        "transform_ms": {k: v * 1e3 for k, v in transform.items()},
        "ingest_speedup_fused":
            ingest["unfused_fixed"] / ingest["fused_bucketed"],
        "transform_speedup_fused":
            transform["unfused_fixed"] / transform["fused_bucketed"],
    }


def main(quick: bool = False, smoke: bool = False) -> dict:
    M, d, Q, C, reps = 1024, 64, 512, 64, 5
    triad_n, cap, m_at, q_batch = 1 << 24, 1024, 128, 64
    if quick:
        M, Q, reps, triad_n = 512, 256, 3, 1 << 22
    if smoke:
        M, d, Q, C, reps, triad_n = 128, 16, 64, 16, 1, 1 << 20
        cap, m_at, q_batch = 128, 16, 8

    peak_gbps, triad_bytes = peak_bandwidth(triad_n, max(reps, 3))
    print(f"[roofline] STREAM-triad peak: {peak_gbps:.1f} GB/s "
          f"({triad_bytes / 1e6:.0f} MB per pass, backend "
          f"{jax.default_backend()})")

    rows = kernel_rows(M, d, Q, C, reps, peak_gbps)
    print(f"[roofline] per-kernel achieved bandwidth at M={M}, d={d}, "
          f"Q={Q}, C={C} (f32)")
    print(f"{'kernel':>16s} {'ms':>9s} {'GB/s':>8s} {'peak%':>6s} "
          f"{'AI f/B':>7s} {'GFLOP/s':>8s}")
    for r in rows:
        gflops = r["flops"] / (r["ms"] / 1e3) / 1e9
        print(f"{r['kernel']:>16s} {r['ms']:9.3f} {r['gbps']:8.2f} "
              f"{100 * r['frac_of_peak']:5.1f}% {r['ai_flop_per_byte']:7.1f} "
              f"{gflops:8.1f}")

    fused = fused_comparison(cap, m_at, d, q_batch, reps)
    print(f"[roofline] fused-vs-unfused at m={fused['m']}, "
          f"M={fused['capacity']} (bucket {fused['bucket']}): "
          f"ingest {fused['ingest_speedup_fused']:.1f}x, "
          f"transform {fused['transform_speedup_fused']:.1f}x "
          f"(gates: >= 1.5x each)")

    result = {
        "backend": jax.default_backend(),
        "dtype": "float32",
        "reps": reps,
        "peak_gbps": peak_gbps,
        "triad_bytes": triad_bytes,
        "kernels": rows,
        "fused": fused,
        "ingest_speedup_fused": fused["ingest_speedup_fused"],
        "transform_speedup_fused": fused["transform_speedup_fused"],
    }
    if smoke:
        bad = [r["kernel"] for r in rows
               if not (np.isfinite(r["gbps"]) and r["gbps"] > 0)]
        if bad or not np.isfinite(peak_gbps) or peak_gbps <= 0:
            raise SystemExit(f"[roofline] smoke gate failed: {bad or 'triad'}")
        print("[roofline] smoke OK (finite, achieved bandwidth > 0), "
              "JSON unchanged")
        return result
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print(f"[roofline] wrote {OUT_PATH}")
    if (fused["ingest_speedup_fused"] < 1.5
            or fused["transform_speedup_fused"] < 1.5):
        print("[roofline] WARNING: fused speedup below the 1.5x gate")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite "
                         "or zero achieved bandwidth")
    args = ap.parse_args()
    main(quick=args.quick, smoke=args.smoke)
