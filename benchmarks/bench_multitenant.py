"""Multi-tenant streaming throughput: vmapped StreamBatch vs a Python loop.

The serving claim of the engine layer: folding one point into B
independent tenant streams should cost ONE vmapped device step, not B
sequential dispatches.  At serving sizes the per-update wall-clock on CPU
is dominated by dispatch overhead and the O(iters·M²) secular bisection —
both of which vmap amortizes across the cohort — so the aggregate
updates/s of the batched path should be several times the loop.

Two paths are timed at the same active count m and capacity M:

* ``loop``   — B independent ``KPCAStream``s, one ``update`` each per
               round (the pre-engine serving pattern: B dispatches).
* ``vmapped``— one ``engine.StreamBatch.update`` per round (one device
               step for the whole cohort, bucketed at max_i m_i).

A second section times a MIXED-size cohort (m_i spread >= 4x): the
``cohorts="max"`` baseline runs every tenant at the bucket of max_i m_i,
while ``cohorts="bucket"`` (bucket-homogeneous cohorts) groups tenants by
their own active bucket and runs one vmapped step per group at that
group's M_b — small tenants stop paying the largest tenant's O(M³) and
O(iters·M²).

Emits ``BENCH_multitenant.json`` at the repo root.  ``--smoke`` runs a
toy configuration, skips the JSON, and exits non-zero on non-finite
output (the ``make bench-smoke`` gate).

    PYTHONPATH=src python -m benchmarks.bench_multitenant [--smoke]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as eng, inkpca, kernels_fn as kf

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_multitenant.json"


def _check_finite(name: str, *arrays) -> None:
    for arr in arrays:
        if not bool(jnp.isfinite(arr).all()):
            raise SystemExit(f"[multitenant] non-finite output in {name}")


def _grow_mixed(cohorts: str, m_per_tenant, capacity: int, d: int,
                min_bucket: int, spec, rng) -> "eng.StreamBatch":
    """A StreamBatch whose tenant i sits at active count m_per_tenant[i]."""
    B = len(m_per_tenant)
    m0 = 4
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=min_bucket)
    seeds = jnp.asarray(rng.normal(size=(B, m0, d)), jnp.float32)
    batch = eng.StreamBatch(seeds, capacity, spec, plan=plan, adjusted=True,
                            cohorts=cohorts)
    targets = np.asarray(m_per_tenant)
    for step in range(int(targets.max()) - m0):
        active = (m0 + step) < targets
        xs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
        batch.update(xs, active=jnp.asarray(active))
    return batch


def bench_mixed_cohort(capacity: int, d: int, rounds: int, smoke: bool,
                       rng) -> dict:
    """Mixed-size cohort: bucket-homogeneous groups vs the max-m_i bucket.

    Tenant sizes are chosen with enough headroom below their buckets that
    no bucket crossing happens inside the timed window, so both paths run
    fully-active steps at a stable bucket assignment.
    """
    if smoke:
        m_profile, min_bucket, rounds = [4, 4, 4, 16], 8, 3
        capacity = min(capacity, 64)
    else:
        # spread 100/16 > 6x: six small tenants in the 32-bucket, two
        # large ones in the 128-bucket; rounds+warmup stays below both
        # bucket boundaries.
        m_profile, min_bucket = [16, 16, 16, 16, 16, 16, 100, 100], 32
        rounds = min(rounds, 12)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    B = len(m_profile)
    xs_rounds = [jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
                 for _ in range(rounds)]

    results = {}
    for cohorts in ("max", "bucket"):
        batch = _grow_mixed(cohorts, m_profile, capacity, d, min_bucket,
                            spec, rng)
        # warm-up at the final bucket assignment
        batch.update(jnp.asarray(rng.normal(size=(B, d)), jnp.float32))
        jax.block_until_ready([st.L for st in batch.working_states()])
        ts = []
        for xs in xs_rounds:
            t0 = time.perf_counter()
            batch.update(xs)
            jax.block_until_ready([st.L for st in batch.working_states()])
            ts.append(time.perf_counter() - t0)
        results[cohorts] = float(np.median(ts))
        _check_finite(f"mixed/{cohorts}",
                      *(st.L for st in batch.working_states()))
    return {
        "m_profile": m_profile,
        "min_bucket": min_bucket,
        "mixed_step_s_max": results["max"],
        "mixed_step_s_bucket": results["bucket"],
        "speedup_bucket_cohorts": results["max"] / results["bucket"],
    }


def main(tenants: int = 8, capacity: int = 512, m_target: int = 64,
         d: int = 16, rounds: int = 20, smoke: bool = False) -> dict:
    if smoke:
        tenants, capacity, m_target, rounds = 4, 64, 16, 5
    rng = np.random.default_rng(0)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(dispatch="bucketed",
                          min_bucket=min(128, capacity))
    m0 = 4

    # Grow both setups to the same active count with the same data.
    seeds = jnp.asarray(rng.normal(size=(tenants, m0, d)), jnp.float32)
    grow = jnp.asarray(rng.normal(size=(m_target - m0, tenants, d)),
                       jnp.float32)
    batch = eng.StreamBatch(seeds, capacity, spec, plan=plan, adjusted=True)
    batch.update_block(grow)
    streams = [inkpca.KPCAStream(seeds[i], capacity, spec, adjusted=True,
                                 plan=plan) for i in range(tenants)]
    for i, s in enumerate(streams):
        s.update_block(grow[:, i])

    xs_warm = jnp.asarray(rng.normal(size=(tenants, d)), jnp.float32)
    # Warm-up: pay compilation for both paths at the current bucket.
    jax.block_until_ready(batch.update(xs_warm).L)
    for i, s in enumerate(streams):
        jax.block_until_ready(s.update(xs_warm[i]).L)

    xs_rounds = [jnp.asarray(rng.normal(size=(tenants, d)), jnp.float32)
                 for _ in range(rounds)]

    # Per-round medians: robust to load spikes on a shared CPU box.
    t_v = []
    for xs in xs_rounds:
        t0 = time.perf_counter()
        states = batch.update(xs)
        jax.block_until_ready(states.L)
        t_v.append(time.perf_counter() - t0)
    t_vmap = float(np.median(t_v))
    _check_finite("vmapped", states.L)

    t_l = []
    for xs in xs_rounds:
        t0 = time.perf_counter()
        for i, s in enumerate(streams):
            s.update(xs[i])
        jax.block_until_ready(streams[-1].state.L)
        t_l.append(time.perf_counter() - t0)
    t_loop = float(np.median(t_l))
    _check_finite("loop", *(s.state.L for s in streams))

    result = {
        "tenants": tenants,
        "capacity": capacity,
        "m": m_target,
        "dim": d,
        "rounds": rounds,
        "backend": jax.default_backend(),
        "dtype": "float32",
        "loop_step_s": t_loop,
        "vmapped_step_s": t_vmap,
        "aggregate_updates_per_s_loop": tenants / t_loop,
        "aggregate_updates_per_s_vmapped": tenants / t_vmap,
        "speedup_vmapped": t_loop / t_vmap,
        "finite": True,
    }
    print(f"[multitenant] B={tenants} m={m_target} M={capacity}: "
          f"loop {t_loop * 1e3:.1f} ms/round "
          f"({result['aggregate_updates_per_s_loop']:.0f} upd/s), "
          f"vmapped {t_vmap * 1e3:.1f} ms/round "
          f"({result['aggregate_updates_per_s_vmapped']:.0f} upd/s) "
          f"-> {result['speedup_vmapped']:.1f}x")

    mixed = bench_mixed_cohort(capacity, d, rounds, smoke, rng)
    result.update(mixed)
    print(f"[multitenant] mixed cohort m={mixed['m_profile']}: "
          f"max-bucket {mixed['mixed_step_s_max'] * 1e3:.1f} ms/step, "
          f"bucket-homogeneous {mixed['mixed_step_s_bucket'] * 1e3:.1f} "
          f"ms/step -> {mixed['speedup_bucket_cohorts']:.1f}x")
    if not smoke:
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
        print(f"[multitenant] wrote {OUT_PATH}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite")
    args = ap.parse_args()
    main(smoke=args.smoke)
