"""Decoupled ingest/serve latency: snapshot queries vs the interleaved
baseline, tenant-axis scaling, and the O(1) snapshot swap.

Three sections, all measuring the double-buffered serving architecture
(``core/serving`` + ``engine.StreamBatch.publish``):

* **latency** — B tenants ingesting at capacity M while query
  micro-batches arrive.  Each step an ingest block and a query batch
  arrive together.  The INTERLEAVED baseline answers queries from the
  working state: the transform data-depends on the update, so it MUST be
  scheduled after it and its latency eats the whole fold (that is the
  seed architecture's p99).  The DECOUPLED path answers from the last
  published immutable snapshot — no data dependency on the pending
  block — so the serving loop schedules the query ahead of the ingest
  dispatch (``IngestServeLoop.step`` order) and p99 stays at pure query
  compute.  (On a single-stream device, work queues FIFO per dispatch
  order; decoupling is exactly what makes the query-first order legal.)
  Queries are also timed IDLE (no pending block) — the smoke gate
  requires decoupled-under-ingest p99 <= 3x idle p99 (plus
  finiteness).

* **tenant scaling** — queries/s of ``distributed.make_tenant_query``
  over a (P_t, 1) tenant mesh at P_t in {1, 2}, one subprocess per P_t
  (the host-device override must precede JAX init).  NOTE: device
  parallel speedup needs real cores — ``host_cores`` is recorded, and on
  a single-core container the ratio is expected ~1.0 (both forced host
  devices share one core); the >= 1.6x acceptance number is meaningful
  only when host_cores >= 2.

* **swap** — the publish/swap cost across capacities M.  The swap a
  serving loop pays is the HOST-SIDE cost of rotating buffer references
  and dispatching the cached publish computation (the snapshot
  materializes off the query path).  The claim is that it never touches
  the (M, M) eigvecs — a copying publication would scale quadratically
  in M; the donated publication tracks at worst the O(M·C + M·d)
  snapshot leaves (``swap_scaling_exponent_vs_M`` <= ~1, vs 2 for a
  copy; not exactly 0 on CPU, which inline-executes small dispatches).
  The blocked publish (materialization) is reported for contrast.

Emits ``BENCH_serving.json`` at the repo root.  ``--smoke`` runs toy
sizes, skips the JSON, and exits non-zero on a non-finite result or
decoupled-under-ingest p99 > 3x idle p99 (the ``make bench-smoke``
gate).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
_MARK = "BENCH_SERVING_RESULT:"


def _pcts(samples) -> dict:
    import numpy as np

    arr = np.asarray(samples, float)
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "max_ms": float(arr.max())}


def _latency_section(smoke: bool) -> dict:
    """Query latency under concurrent ingest: decoupled vs interleaved."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import engine as eng, kernels_fn as kf, serving

    if smoke:
        B, M, d, warmup, rounds, nq = 4, 64, 8, 8, 8, 4
    else:
        # warmup puts m just past a bucket crossing (144 -> bucket 256)
        # so the 2*rounds ingested points during timing stay inside one
        # bucket — no recompile spike lands in either path's p99.
        B, M, d, warmup, rounds, nq = 8, 512, 16, 140, 30, 8
    rng = np.random.default_rng(0)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(matmul="jnp", dispatch="bucketed",
                          serve_components=8)
    sb = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d)), jnp.float32),
                         M, spec, plan=plan, adjusted=True,
                         dtype=jnp.float32)
    for _ in range(warmup):
        st = sb.update(jnp.asarray(rng.normal(size=(B, d)), jnp.float32))
    jax.block_until_ready(st.L)
    snaps = sb.publish()
    n_comp = plan.serve_components

    # Both serving paths jitted end-to-end, as a real loop would run them:
    # the decoupled query reads the frozen snapshot; the interleaved
    # baseline's transform reads the working state the in-flight update
    # writes, so it queues behind the whole update.
    qfn = jax.jit(lambda s, x: serving.query_batch(s, x, spec=spec,
                                                   plan=plan))
    tfn = jax.jit(lambda s, x: jax.vmap(
        lambda si, xi: eng.transform_state(si, xi, n_components=n_comp,
                                           spec=spec, adjusted=True,
                                           plan=plan))(s, x))

    def qbatch():
        return jnp.asarray(rng.normal(size=(B, nq, d)), jnp.float32)

    jax.block_until_ready(qfn(snaps, qbatch()))
    jax.block_until_ready(tfn(st, qbatch()))

    idle, dec, inter = [], [], []
    for _ in range(rounds):
        q = qbatch()
        # Idle: no update in flight.
        t0 = time.perf_counter()
        jax.block_until_ready(qfn(snaps, q))
        idle.append((time.perf_counter() - t0) * 1e3)

        # Decoupled: block + queries arrive together; the snapshot query
        # has no data dependency on the block, so it is served FIRST
        # (IngestServeLoop.step order), then the ingest is dispatched.
        t0 = time.perf_counter()
        jax.block_until_ready(qfn(snaps, q))
        dec.append((time.perf_counter() - t0) * 1e3)
        st = sb.update(jnp.asarray(rng.normal(size=(B, d)), jnp.float32))
        jax.block_until_ready(st.L)
        snaps = sb.publish()

        # Interleaved baseline: the transform reads the working state the
        # just-dispatched update writes — it queues behind the update.
        st = sb.update(jnp.asarray(rng.normal(size=(B, d)), jnp.float32))
        t0 = time.perf_counter()
        y = tfn(st, q)
        jax.block_until_ready(y)
        inter.append((time.perf_counter() - t0) * 1e3)

    finite = bool(jnp.isfinite(y).all()) and all(
        bool(jnp.isfinite(st.L).all()) for st in sb.working_states())
    out = {
        "tenants": B, "capacity": M, "dim": d, "query_batch": nq,
        "warmup_points": warmup, "rounds": rounds,
        "m_final": int(np.max(np.asarray(sb.states.m))),
        "idle": _pcts(idle), "decoupled": _pcts(dec),
        "interleaved": _pcts(inter),
        "p99_speedup_decoupled":
            _pcts(inter)["p99_ms"] / _pcts(dec)["p99_ms"],
        "p99_under_ingest_over_idle":
            _pcts(dec)["p99_ms"] / _pcts(idle)["p99_ms"],
        "finite": finite,
    }
    print(f"[serving] B={B} M={M}: query p99 idle "
          f"{out['idle']['p99_ms']:.2f} ms, decoupled-under-ingest "
          f"{out['decoupled']['p99_ms']:.2f} ms, interleaved "
          f"{out['interleaved']['p99_ms']:.2f} ms -> "
          f"{out['p99_speedup_decoupled']:.1f}x decoupled p99 win")
    return out


def _swap_section(smoke: bool) -> dict:
    """Publish/swap cost across capacities: the host-side swap must be
    flat in M (O(1)); blocked materialization grows O(M·C + M·d)."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import engine as eng
    from repro.core import inkpca, kernels_fn as kf, serving

    Ms = (64, 128) if smoke else (256, 512, 1024)
    d, m_at, rounds = (8, 12, 5) if smoke else (16, 48, 15)
    rng = np.random.default_rng(1)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    per_m = []
    for M in Ms:
        X = rng.normal(size=(m_at, d)).astype(np.float32)
        state = inkpca.init_state(jnp.asarray(X[:4]), M, spec, adjusted=True,
                                  dtype=jnp.float32)
        state = eng.Engine(spec, eng.DEFAULT_PLAN._replace(
            dispatch="bucketed")).update_block(state, jnp.asarray(X[4:]))
        buf = serving.DoubleBuffer(state, n_components=8)
        for _ in range(3):                    # reach donation steady state
            jax.block_until_ready(buf.publish(state).S)
        swap_ms, publish_ms = [], []
        for _ in range(rounds):
            t0 = time.perf_counter()
            snap = buf.publish(state)         # dispatch + buffer flip only
            swap_ms.append((time.perf_counter() - t0) * 1e3)
            jax.block_until_ready(snap.S)
            t0 = time.perf_counter()
            jax.block_until_ready(buf.publish(state).S)
            publish_ms.append((time.perf_counter() - t0) * 1e3)
        per_m.append({"capacity": M,
                      "swap_ms": float(np.median(swap_ms)),
                      "publish_blocked_ms": float(np.median(publish_ms))})
        print(f"[serving] M={M}: swap {per_m[-1]['swap_ms']:.3f} ms "
              f"(host flip + dispatch), publish blocked "
              f"{per_m[-1]['publish_blocked_ms']:.3f} ms")
    swaps = [r["swap_ms"] for r in per_m]
    # The O(1)-vs-M claim, checked as a scaling exponent: the swap must
    # track the O(M·C + M·d) snapshot leaves at worst (exponent <= ~1;
    # CPU inline-executes small dispatches, so it isn't exactly 0), and
    # NEVER the (M, M) eigvecs a copying publication would pay
    # (exponent 2).
    exponent = (float(np.log(swaps[-1] / swaps[0])
                      / np.log(Ms[-1] / Ms[0])) if swaps[0] > 0 else 0.0)
    return {"m_active": m_at, "per_capacity": per_m,
            "swap_ratio_max_over_min": max(swaps) / min(swaps),
            "swap_scaling_exponent_vs_M": exponent}


def _worker_scaling(p_tenant: int, smoke: bool) -> dict:
    """Runs in a subprocess with p_tenant forced host devices: aggregate
    queries/s of the tenant-sharded query path."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import distributed as dist, engine as eng
    from repro.core import kernels_fn as kf

    assert jax.device_count() >= p_tenant, (jax.device_count(), p_tenant)
    if smoke:
        B, M, d, warmup, nq, rounds = 4, 64, 8, 6, 4, 10
    else:
        B, M, d, warmup, nq, rounds = 8, 512, 16, 60, 8, 40
    rng = np.random.default_rng(2)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    plan = eng.UpdatePlan(matmul="jnp", dispatch="bucketed",
                          serve_components=8)
    sb = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d)), jnp.float32),
                         M, spec, plan=plan, adjusted=True,
                         dtype=jnp.float32)
    for _ in range(warmup):
        sb.update(jnp.asarray(rng.normal(size=(B, d)), jnp.float32))
    snaps = sb.publish()
    mesh = dist.make_tenant_mesh(p_tenant, 1)
    qfn = dist.make_tenant_query(mesh, spec, plan=plan)
    q = jnp.asarray(rng.normal(size=(B, nq, d)), jnp.float32)
    y = qfn(snaps, q)                          # compile
    jax.block_until_ready(y)
    if not bool(jnp.isfinite(y).all()):
        raise SystemExit(f"[serving] non-finite queries at P_t={p_tenant}")
    t0 = time.perf_counter()
    for _ in range(rounds):
        y = qfn(snaps, q)
        jax.block_until_ready(y)
    total = time.perf_counter() - t0
    qps = B * nq * rounds / total
    print(f"[serving] P_t={p_tenant}: {qps:.0f} queries/s "
          f"({B} tenants x {nq} queries x {rounds} rounds)")
    return {"P_t": p_tenant, "tenants": B, "capacity": M,
            "query_batch": nq, "rounds": rounds, "queries_per_s": qps}


def _tenant_scaling(smoke: bool) -> dict:
    per_pt = []
    for p_t in (1, 2):
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (f"{flags} "
                            f"--xla_force_host_platform_device_count={p_t}")
        env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent
                                 / "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "benchmarks.bench_serving",
               "--worker", str(p_t)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              cwd=Path(__file__).resolve().parent.parent)
        sys.stdout.write(proc.stdout.replace(_MARK, "# "))
        sys.stderr.write(proc.stderr)
        if proc.returncode != 0:
            raise SystemExit(f"[serving] worker P_t={p_t} failed "
                             f"(exit {proc.returncode})")
        payload = [ln for ln in proc.stdout.splitlines()
                   if ln.startswith(_MARK)]
        per_pt.append(json.loads(payload[-1][len(_MARK):]))
    ratio = per_pt[1]["queries_per_s"] / per_pt[0]["queries_per_s"]
    cores = os.cpu_count() or 1
    print(f"[serving] tenant-axis scaling P_t=2 vs 1: {ratio:.2f}x "
          f"(host_cores={cores}; the 1.6x target needs >= 2 real cores)")
    return {"per_tenant_axis": per_pt, "qps_ratio_pt2_over_pt1": ratio,
            "host_cores": cores,
            "note": "forced host devices share physical cores; the "
                    ">=1.6x acceptance ratio requires host_cores >= 2"}


def main(smoke: bool = False) -> dict:
    latency = _latency_section(smoke)
    swap = _swap_section(smoke)
    scaling = _tenant_scaling(smoke)
    result = {
        "backend": "cpu", "dtype": "float32",
        "host_cores": os.cpu_count() or 1,
        "latency_under_ingest": latency,
        "snapshot_swap": swap,
        "tenant_scaling": scaling,
    }
    if smoke:
        ratio = latency["p99_under_ingest_over_idle"]
        if not latency["finite"]:
            raise SystemExit("[serving] smoke gate failed: non-finite")
        if ratio > 3.0:
            raise SystemExit(f"[serving] smoke gate failed: decoupled p99 "
                             f"under ingest is {ratio:.1f}x idle (> 3x)")
        print(f"[serving] smoke OK (finite, p99 under ingest "
              f"{ratio:.2f}x idle <= 3x), JSON unchanged")
        return result
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print(f"[serving] wrote {OUT_PATH}")
    if latency["p99_speedup_decoupled"] < 5.0:
        print("[serving] WARNING: decoupled p99 win below the 5x gate")
    if scaling["qps_ratio_pt2_over_pt1"] < 1.6 and result["host_cores"] >= 2:
        print("[serving] WARNING: tenant-axis scaling below the 1.6x gate")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite "
                         "or p99-under-ingest > 3x idle")
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker is not None:
        res = _worker_scaling(args.worker, args.smoke)
        print(_MARK + json.dumps(res))
    else:
        main(smoke=args.smoke)
