"""Wall-clock cross-check (CPU): per-step cost of the incremental update vs
a from-scratch batch eigh, as m grows — the practical speedup that
motivates the paper's algorithm in the streaming setting, plus the
incremental-Nyström landmark-add cost.

(CPU timings are indicative only; the TPU-path cost model lives in the
dry-run §Roofline. This benchmark demonstrates the *scaling*, ~m² per
update vs ~m³ re-batch once jit overheads are out.)
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import inkpca, kernels_fn as kf

jax.config.update("jax_enable_x64", True)


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main() -> dict:
    rng = np.random.default_rng(0)
    results = {}
    print(f"[timing] {'m':>6s} {'incr_update_ms':>15s} "
          f"{'batch_eigh_ms':>14s} {'ratio':>7s}")
    for m in (64, 128, 256, 512):
        d = 10
        X = rng.normal(size=(m + 1, d))
        spec = kf.KernelSpec(name="rbf", sigma=float(d))
        state = inkpca.init_state(jnp.asarray(X[:m]), m + 1, spec,
                                  adjusted=True, dtype=jnp.float64)
        a, k_new = inkpca._masked_row(state, jnp.asarray(X[m]), spec)

        t_inc = _time(lambda s, a_, k_, x_: inkpca.update_adjusted(
            s, a_, k_, x_).L.block_until_ready(), state, a, k_new,
            jnp.asarray(X[m]))

        K = kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec)
        Kc = kf.center_gram(K)
        t_batch = _time(lambda M: jnp.linalg.eigh(M)[0].block_until_ready(),
                        Kc)
        results[m] = {"incremental_s": t_inc, "batch_s": t_batch}
        print(f"{m:6d} {t_inc * 1e3:15.2f} {t_batch * 1e3:14.2f} "
              f"{t_batch / t_inc:7.2f}")
    return results


if __name__ == "__main__":
    main()
