"""Paper Fig. 2 reproduction: accuracy of the *incrementally computed*
Nyström approximation — ‖K − K̃‖ (fro/spectral/trace) as landmarks are
added one at a time, on the first 1000 observations of each dataset.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf, nystrom
from repro.data.uci_like import load_dataset

jax.config.update("jax_enable_x64", True)


def run_once(dataset: str, n: int, m0: int, m_max: int, seed: int,
             checkpoints=(20, 40, 80, 120, 160, 200)) -> dict:
    X = load_dataset(dataset, n=n, seed=0)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)              # random landmark order
    sigma = float(kf.median_heuristic(jnp.asarray(X)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))

    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[order[:m0]]),
                                 capacity=max(checkpoints) + m0, spec=spec,
                                 dtype=jnp.float64)
    out = {}
    m = m0
    for ck in checkpoints:
        while m < ck + m0:
            state = nystrom.add_landmark(state, jnp.asarray(X),
                                         jnp.asarray(X[order[m]]), spec)
            m += 1
        Kt = np.asarray(nystrom.reconstruct_tilde(state))
        e = nystrom.approximation_error(jnp.asarray(K), jnp.asarray(Kt))
        out[ck] = {"fro": e.fro, "spectral": e.spectral, "trace": e.trace}
    return out


def main(runs: int = 3, n: int = 1000) -> dict:
    results = {}
    for dataset in ("magic", "yeast"):
        per_ck: dict = {}
        for r in range(runs):
            one = run_once(dataset, n=n, m0=20, m_max=220, seed=r)
            for ck, ns in one.items():
                per_ck.setdefault(ck, []).append(ns)
        results[dataset] = {
            ck: {k: float(np.mean([x[k] for x in v])) for k in v[0]}
            for ck, v in per_ck.items()}
        print(f"[fig2] {dataset}: ‖K − K̃‖ vs landmarks (n={n}, "
              f"mean of {runs})")
        for ck, ns in results[dataset].items():
            print(f"  m=20+{ck:<4d} fro={ns['fro']:.4e} "
                  f"spec={ns['spectral']:.4e} trace={ns['trace']:.4e}")
    return results


if __name__ == "__main__":
    main()
