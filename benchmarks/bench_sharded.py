"""Sharded rank-one update throughput: square-block vs rectangular-pruned.

The PR-2 sharded path rotated each device's FULL (M/P, M) row block against
a dense (M, M) factor — active-tile pruning was lost the moment P > 1
because the Pallas kernels required square operands.  The rectangular
kernels (+ the bucketed local slice in ``core/distributed.py``) restore
m-scaling at any P: each device rotates a (min(M/P, M_b), M_b) rectangle
and the replicated secular solve runs at O(M_b²·iters).

Three comparisons per device count P ∈ {1, 2, 4} (CPU devices via the
``--xla_force_host_platform_device_count`` XLA flag, one subprocess per P
since the flag must be set before JAX initializes):

* ``square``   — ``make_sharded_update`` with the fixed-dispatch plan
                 (the PR-2 square-block path: O(M³/P) regardless of m).
* ``rect``     — the same update with ``dispatch="bucketed"``: the
                 rectangular-pruned path, O(M_b²·m/P) rotation work.
* ``pair_fallback_{on,off}`` — the fused ±sigma sharded pair with and
                 without the collective-balanced merge fallback (the
                 fallback costs one extra O(M) psum and a cond).

Emits ``BENCH_sharded.json`` at the repo root.  ``--smoke`` runs toy
sizes, skips the JSON, and exits non-zero on non-finite output (the
``make bench-smoke`` gate).

    PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_sharded.json"
_MARK = "BENCH_SHARDED_RESULT:"


def _worker(P: int, smoke: bool) -> dict:
    """Runs inside a subprocess with P forced host devices."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import distributed as dkpca, engine as eng, rankone

    assert jax.device_count() >= P, (jax.device_count(), P)
    if smoke:
        M, m, rounds, min_bucket = 64, 12, 3, 16
    else:
        M, m, rounds, min_bucket = 512, 64, 15, 128

    rng = np.random.default_rng(0)
    A = rng.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M, np.float32)
    U = np.eye(M, dtype=np.float32)
    L[:m] = lam
    U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float32(0.0))
    U = jnp.asarray(U)

    def vvec(seed):
        v = np.zeros(M, np.float32)
        v[:m] = np.random.default_rng(seed).normal(size=m)
        return jnp.asarray(v)

    mesh = jax.make_mesh((P,), ("data",))
    mj = jnp.int32(m)

    def _median_time(fn, args_of_round) -> float:
        out = fn(*args_of_round(0))            # compile
        jax.block_until_ready(out)
        ts = []
        for r in range(rounds):
            args = args_of_round(r + 1)
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        if not all(bool(jnp.isfinite(o).all()) for o in out):
            raise SystemExit(f"[sharded] non-finite output at P={P}")
        return float(np.median(ts))

    plans = {
        "square": eng.UpdatePlan(dispatch="fixed", matmul="jnp"),
        "rect": eng.UpdatePlan(dispatch="bucketed", matmul="jnp",
                               min_bucket=min_bucket),
    }
    res: dict = {"P": P, "M": M, "m": m, "rounds": rounds,
                 "min_bucket": min_bucket}
    for name, plan in plans.items():
        upd = dkpca.make_sharded_update(mesh, plan=plan)
        res[f"update_s_{name}"] = _median_time(
            upd, lambda r: (L, U, vvec(r), jnp.float32(1.3), mj))
    res["speedup_rect"] = res["update_s_square"] / res["update_s_rect"]

    for name, fb in (("on", True), ("off", False)):
        plan = eng.UpdatePlan(dispatch="bucketed", matmul="jnp2",
                              min_bucket=min_bucket, merge_fallback=fb)
        pair = dkpca.make_sharded_update_pair(mesh, plan=plan)
        res[f"pair_s_fallback_{name}"] = _median_time(
            pair, lambda r: (L, U, vvec(2 * r), jnp.float32(1.3),
                             vvec(2 * r + 1), jnp.float32(-1.3), mj))
    res["fallback_overhead"] = (res["pair_s_fallback_on"]
                                / res["pair_s_fallback_off"])
    print(f"[sharded] P={P} M={M} m={m}: square "
          f"{res['update_s_square'] * 1e3:.1f} ms, rect-pruned "
          f"{res['update_s_rect'] * 1e3:.1f} ms -> "
          f"{res['speedup_rect']:.1f}x; fused pair fallback on/off "
          f"{res['pair_s_fallback_on'] * 1e3:.1f}/"
          f"{res['pair_s_fallback_off'] * 1e3:.1f} ms")
    return res


def main(smoke: bool = False) -> dict:
    # Smoke gates one multi-device config only: compile time dominates at
    # toy sizes, and P=2 already exercises psums, slicing and the cond.
    device_counts = (2,) if smoke else (1, 2, 4)
    per_p = []
    for P in device_counts:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (f"{flags} "
                            f"--xla_force_host_platform_device_count={P}")
        env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent
                                 / "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "benchmarks.bench_sharded",
               "--worker", str(P)]
        if smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              cwd=Path(__file__).resolve().parent.parent)
        sys.stdout.write(proc.stdout.replace(_MARK, "# "))
        sys.stderr.write(proc.stderr)
        if proc.returncode != 0:
            raise SystemExit(f"[sharded] worker P={P} failed "
                             f"(exit {proc.returncode})")
        payload = [ln for ln in proc.stdout.splitlines()
                   if ln.startswith(_MARK)]
        per_p.append(json.loads(payload[-1][len(_MARK):]))

    result = {"backend": "cpu", "dtype": "float32", "per_device_count": per_p}
    if not smoke:
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
        print(f"[sharded] wrote {OUT_PATH}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes, no JSON, non-zero exit on non-finite")
    ap.add_argument("--worker", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker is not None:
        res = _worker(args.worker, args.smoke)
        print(_MARK + json.dumps(res))
    else:
        main(smoke=args.smoke)
